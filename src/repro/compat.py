"""Version compatibility helpers for the jax API surface.

The codebase targets the modern ``jax.shard_map`` entry point; on older
releases (< 0.5, e.g. the 0.4.x in this container) that lives at
``jax.experimental.shard_map.shard_map`` and the replication-check kwarg
is ``check_rep`` rather than ``check_vma``.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
