"""Fault tolerance: supervised restart/replay over a Checkpointer, a
straggler watchdog, and failure injection (for tests).

On a real fleet a supervisor wraps per-unit-of-work execution; a host
failure surfaces as an exception (collective timeout / halted device) →
restore from the last committed checkpoint and replay.  The restart
accounting and budget live in the generic :class:`Supervisor`;
:class:`TrainSupervisor` (step-indexed train loop — the data pipeline in
repro.data.pipeline is step-indexed, so replay is exact) and
``repro.serve.durable.ServiceSupervisor`` (ticket-journaled query
service) both subclass it.

The watchdog implements the paper-adjacent straggler story at the system
level: step times exceeding ``threshold ×`` a running median are flagged;
the fleet hook (``on_straggler``) would evict/reshuffle the slow host —
here it feeds metrics and tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.checkpoint.checkpointer import Checkpointer


@dataclasses.dataclass
class WatchdogStats:
    steps: int = 0
    flagged: int = 0
    median_s: float = 0.0


class StragglerWatchdog:
    """Flags steps slower than ``threshold`` x the running median."""

    def __init__(self, threshold: float = 3.0, window: int = 32,
                 on_straggler: Callable[[int, float], None] | None = None):
        self.threshold = threshold
        self.window = window
        self.times: list[float] = []
        self.stats = WatchdogStats()
        self.on_straggler = on_straggler

    def observe(self, step: int, dt: float) -> bool:
        self.stats.steps += 1
        hist = self.times[-self.window:]
        flagged = False
        if len(hist) >= 8:
            med = sorted(hist)[len(hist) // 2]
            self.stats.median_s = med
            if dt > self.threshold * med:
                flagged = True
                self.stats.flagged += 1
                if self.on_straggler:
                    self.on_straggler(step, dt)
        self.times.append(dt)
        return flagged


class Supervisor:
    """Restart/replay core shared by the train loop and the query
    service: counts faults against a restart budget and resolves which
    committed step to restore from.  Subclasses own the work loop and
    what "replay" means (step-indexed batches vs journaled tickets)."""

    def __init__(self, ckpt: Checkpointer, *, max_restarts: int = 10):
        self.ckpt = ckpt
        self.max_restarts = max_restarts
        self.restarts = 0

    def recover_step(self, exc: BaseException, *, what: str = "work",
                     log=print) -> int:
        """Account one fault.  Raises if the restart budget is exhausted
        or there is nothing committed to restore from; otherwise returns
        the step to restore (after draining any in-flight async save)."""
        self.restarts += 1
        if self.restarts > self.max_restarts:
            raise RuntimeError(
                f"exceeded {self.max_restarts} restarts") from exc
        last = self.ckpt.latest_step()
        log(f"[supervisor] {what} failed ({type(exc).__name__}: {exc}); "
            f"restoring from {last}")
        if last is None:
            raise exc
        self.ckpt.wait()
        return last


class TrainSupervisor(Supervisor):
    """Run a step function with periodic async checkpoints and
    restore-on-failure.  ``fail_injector(step)`` raising simulates a node
    loss (tests); any exception triggers restore + replay."""

    def __init__(self, ckpt: Checkpointer, *, save_every: int = 50,
                 max_restarts: int = 10,
                 watchdog: StragglerWatchdog | None = None):
        super().__init__(ckpt, max_restarts=max_restarts)
        self.save_every = save_every
        self.watchdog = watchdog or StragglerWatchdog()

    def run(self, state: Any, step_fn, data_fn, *, start_step: int,
            num_steps: int, fail_injector=None, log_every: int = 10,
            log=print) -> tuple[Any, int, list]:
        """state: pytree; step_fn(state, step, batch) -> (state, metrics).
        Returns (state, final_step, metric_log)."""
        import jax
        # Pristine restore template captured BEFORE any step runs: after
        # a fault the in-flight ``state`` may hold corrupted buffers
        # (NaN-poisoned or halted-device arrays) — restore must only
        # depend on its shapes/dtypes, never its values.
        template = jax.eval_shape(lambda: state)
        metrics_log = []
        step = start_step
        while step < num_steps:
            try:
                t0 = time.time()
                if fail_injector is not None:
                    fail_injector(step)
                batch = data_fn(step)
                state, metrics = step_fn(state, step, batch)
                dt = time.time() - t0
                slow = self.watchdog.observe(step, dt)
                if slow:
                    log(f"[watchdog] step {step} took {dt:.3f}s "
                        f"(median {self.watchdog.stats.median_s:.3f}s)")
                step += 1
                if step % log_every == 0 or step == num_steps:
                    metrics_log.append((step, jax_device_get(metrics)))
                    log(f"[train] step {step}: {metrics_log[-1][1]}")
                if step % self.save_every == 0:
                    self.ckpt.save(step, state, blocking=False)
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — any fault → restart
                self.recover_step(e, what=f"step {step}", log=log)
                state, step = self.ckpt.restore(template)
        self.ckpt.wait()
        self.ckpt.save(num_steps, state, blocking=True)
        return state, step, metrics_log


def jax_device_get(tree):
    import jax
    return jax.tree.map(lambda x: float(x) if hasattr(x, "shape") and
                        x.shape == () else x, jax.device_get(tree))
