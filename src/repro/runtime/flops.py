"""Exact FLOP / byte accounting by walking jaxprs.

``compiled.cost_analysis()`` on the CPU backend counts ``while``/``scan``
bodies ONCE regardless of trip count (verified in EXPERIMENTS.md §Dry-run),
so roofline compute terms would be wildly understated for scanned layer
stacks.  This walker recurses through scan/while/pjit/remat/cond with the
correct multipliers and produces:

* ``flops``      — total floating-point ops (dots = 2·M·N·K, elementwise = n)
* ``hbm_bytes``  — *unfused upper bound*: every op's operands + results
  (XLA fusion only lowers this; the roofline table reports it alongside the
  model-state lower bound computed analytically)
* ``dot_flops``  — matmul-only FLOPs (MXU share)
* per-primitive breakdowns for §Perf iteration.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any

import jax
import jax.numpy as jnp
from jax.extend import core as jcore


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    dot_flops: float = 0.0
    bytes: float = 0.0
    by_prim: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.dot_flops += other.dot_flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.by_prim.items():
            self.by_prim[k] += v * mult


def _aval_bytes(aval) -> int:
    if not hasattr(aval, "shape"):
        return 0
    n = 1
    for d in aval.shape:
        n *= int(d)
    return n * jnp.dtype(aval.dtype).itemsize


def _size(aval) -> int:
    n = 1
    for d in getattr(aval, "shape", ()):
        n *= int(d)
    return n


# elementwise-ish primitives costed at 1 flop per output element
_CHEAP = {
    "add", "sub", "mul", "div", "max", "min", "neg", "abs", "sign",
    "exp", "log", "tanh", "logistic", "rsqrt", "sqrt", "pow", "integer_pow",
    "erf", "floor", "ceil", "round", "select_n", "clamp", "and", "or",
    "not", "xor", "eq", "ne", "lt", "le", "gt", "ge", "expm1", "log1p",
    "cos", "sin", "stop_gradient", "convert_element_type", "nextafter",
    "rem", "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "squeeze", "cumsum", "cummax", "cummin", "cumprod", "is_finite",
}
_FREE = {
    "reshape", "broadcast_in_dim", "transpose", "slice", "concatenate",
    "pad", "rev", "iota", "dynamic_slice", "dynamic_update_slice",
    "copy", "device_put", "sharding_constraint", "split",
    "squeeze", "expand_dims", "bitcast_convert_type", "real", "imag",
    "create_token", "optimization_barrier", "pvary",
}
_SUBJAXPR_MULT_KEYS = ("length",)


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = 1
    for d in lb:
        batch *= lhs.shape[d]
    contract = 1
    for d in lc:
        contract *= lhs.shape[d]
    m = 1
    for i, d in enumerate(lhs.shape):
        if i not in lc and i not in lb:
            m *= d
    n = 1
    for i, d in enumerate(rhs.shape):
        if i not in rc and i not in rb:
            n *= d
    return 2.0 * batch * m * n * contract


def jaxpr_cost(jaxpr: jcore.Jaxpr) -> Cost:
    cost = Cost()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        out_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)
        in_bytes = sum(_aval_bytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))
        sub = _subjaxprs(eqn)
        if sub:
            mult = _multiplier(eqn)
            inner = Cost()
            for sj in sub:
                inner.add(jaxpr_cost(sj))
            cost.add(inner, mult)
            cost.by_prim[name] += inner.flops * mult
            continue
        if name == "dot_general":
            f = _dot_flops(eqn)
            cost.flops += f
            cost.dot_flops += f
            cost.bytes += in_bytes + out_bytes
            cost.by_prim[name] += f
        elif name in _FREE:
            # layout/movement: bytes only (XLA usually fuses; upper bound)
            cost.bytes += out_bytes
        elif name in _CHEAP:
            f = sum(_size(v.aval) for v in eqn.outvars)
            cost.flops += f
            cost.bytes += in_bytes + out_bytes
            cost.by_prim[name] += f
        elif name.startswith("reduce_") or name in ("argmax", "argmin"):
            f = sum(_size(v.aval) for v in eqn.invars if hasattr(v, "aval"))
            cost.flops += f
            cost.bytes += in_bytes + out_bytes
            cost.by_prim[name] += f
        elif name in ("gather", "scatter", "scatter-add", "scatter_add",
                      "scatter_min", "scatter_max", "take_along_axis",
                      "sort", "top_k", "argsort"):
            f = out_bytes  # index math ~ O(out)
            cost.flops += f
            cost.bytes += in_bytes + out_bytes
            cost.by_prim[name] += f
        else:
            # default: elementwise-ish
            f = sum(_size(v.aval) for v in eqn.outvars)
            cost.flops += f
            cost.bytes += in_bytes + out_bytes
            cost.by_prim[name] += f
    return cost


def _subjaxprs(eqn):
    out = []
    for k, v in eqn.params.items():
        if isinstance(v, jcore.ClosedJaxpr):
            out.append(v.jaxpr)
        elif isinstance(v, jcore.Jaxpr):
            out.append(v)
        elif k == "branches" and isinstance(v, (tuple, list)):
            # cond: cost of the most expensive branch
            costs = [(jaxpr_cost(b.jaxpr if hasattr(b, "jaxpr") else b), b)
                     for b in v]
            best = max(costs, key=lambda cb: cb[0].flops)
            out.append(best[1].jaxpr if hasattr(best[1], "jaxpr") else best[1])
    return out


def _multiplier(eqn) -> float:
    name = eqn.primitive.name
    if name == "scan":
        return float(eqn.params.get("length", 1))
    if name == "while":
        # model code uses bounded loops only via scan; graph algorithms use
        # while — callers report those separately.
        return 1.0
    return 1.0


def cost_of(fn, *args, **kwargs) -> Cost:
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return jaxpr_cost(closed.jaxpr)
