"""Logical-axis sharding rules with divisibility fallback.

Every parameter / activation in the framework carries a tuple of *logical*
axis names (e.g. ``("vocab", "embed")``).  A :class:`ShardingRules` table maps
logical names to mesh axis names (or ``None`` for replicated).  The mapping is
applied with a divisibility check: a dimension that does not divide the mesh
axis size falls back to replication (e.g. ``kv_heads=8`` on a 16-way ``model``
axis).  This mirrors what production frameworks (MaxText, EasyLM) do and keeps
every assigned architecture shardable on the fixed production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> mesh axis (or tuple of mesh axes, or None).
LogicalRules = Mapping[str, Any]

# The default TRAIN rules for the production mesh ("pod"?, "data", "model"):
#   - FSDP: the model/embed dimension of weights shards over "data".
#   - TP:   heads / ffn / vocab / expert dimensions shard over "model".
#   - DP:   the batch dimension of activations shards over ("pod", "data").
#   - SP:   long KV caches shard their sequence dimension over "model".
TRAIN_RULES: LogicalRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_seq": "model",       # sequence parallelism (rcfg.seq_parallel)
    "embed": "data",          # FSDP axis for params
    "act_embed": None,        # activations keep embed replicated
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_capacity": "data",
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv_kernel": None,
    "cache_seq": "model",
    "frames": None,
    "norm": None,
    "pos": None,
}

# Serving baseline uses the same weight layout (ZeRO-3 style: XLA
# all-gathers weights over "data" per layer).
SERVE_RULES: LogicalRules = dict(TRAIN_RULES)

# Optimized serving layout (§Perf iteration "serve-tp"): TP-only bf16
# weights — no FSDP dimension, so decode/prefill never re-gathers weights.
# Viable whenever params_bf16/16 fits HBM (all assigned archs except the
# two >200B MoE giants, which keep expert-sharding over "model" anyway).
SERVE_TP_RULES: LogicalRules = dict(TRAIN_RULES)
SERVE_TP_RULES.update({"embed": None})


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: LogicalRules

    def spec_for(self, logical_axes: Sequence[str | None],
                 shape: Sequence[int], mesh: Mesh) -> P:
        """Build a PartitionSpec, dropping non-dividing or missing axes."""
        used: set[str] = set()
        out = []
        for dim, name in zip(shape, logical_axes):
            mesh_axes = self.rules.get(name) if name is not None else None
            if mesh_axes is None:
                out.append(None)
                continue
            if isinstance(mesh_axes, str):
                mesh_axes = (mesh_axes,)
            # keep only axes present in the mesh, unused so far, and dividing
            picked = []
            size = 1
            for ax in mesh_axes:
                if ax in mesh.shape and ax not in used:
                    if dim % (size * mesh.shape[ax]) == 0:
                        picked.append(ax)
                        size *= mesh.shape[ax]
            for ax in picked:
                used.add(ax)
            if not picked:
                out.append(None)
            elif len(picked) == 1:
                out.append(picked[0])
            else:
                out.append(tuple(picked))
        # trim trailing Nones for cleanliness
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding_for(self, logical_axes: Sequence[str | None],
                     shape: Sequence[int], mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.spec_for(logical_axes, shape, mesh))


# ---------------------------------------------------------------------------
# Path-based logical-axes resolution for parameter trees.
#
# Parameter names are globally meaningful in this codebase; this table is the
# single source of truth for how each weight shards.  Disambiguation uses the
# parent key ("mixer"/"mlp"/"cross") and the rank (MoE weights are 3-D).
# Stacked block parameters (under "blocks"/"encoder"/"decoder") get a leading
# replicated layer axis.
# ---------------------------------------------------------------------------

_NAME_AXES = {
    "embedding": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "pos_embedding": ("pos", "embed"),
    "enc_pos": ("pos", "embed"),
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "head_dim"),
    "wv": ("embed", "kv_heads", "head_dim"),
    "bq": ("heads", "head_dim"),
    "bk": ("kv_heads", "head_dim"),
    "bv": ("kv_heads", "head_dim"),
    "q_norm": ("norm",),
    "k_norm": ("norm",),
    "router": ("embed", "experts"),
    "wz": ("embed", "ssm_inner"),
    "wx": ("embed", "ssm_inner"),
    "wB": ("embed", "ssm_state"),
    "wC": ("embed", "ssm_state"),
    "wdt": ("embed", "ssm_heads"),
    "conv_x": ("conv_kernel", "ssm_inner"),
    "conv_B": ("conv_kernel", "ssm_state"),
    "conv_C": ("conv_kernel", "ssm_state"),
    "A_log": ("ssm_heads",),
    "D": ("ssm_heads",),
    "dt_bias": ("ssm_heads",),
}

_STACK_KEYS = ("blocks", "encoder", "decoder")


_CACHE_AXES = {
    "k": (None, "batch", "cache_seq", "kv_heads", "head_dim"),
    "v": (None, "batch", "cache_seq", "kv_heads", "head_dim"),
    "cross_k": (None, "batch", "cache_seq", "kv_heads", "head_dim"),
    "cross_v": (None, "batch", "cache_seq", "kv_heads", "head_dim"),
    "pos": (None, None),
    "conv": (None, "batch", None, "ssm_inner"),
    "ssm": (None, "batch", "ssm_heads", None, None),
}


def resolve_axes(path, ndim: int) -> tuple:
    """Logical axes for the parameter at a tree_util key path."""
    keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = keys[-1]
    parents = keys[:-1]

    # KV/SSM cache leaves (decode path)
    if name in _CACHE_AXES and len(_CACHE_AXES[name]) == ndim and \
            not any(k in _STACK_KEYS for k in parents if isinstance(k, str)):
        return _CACHE_AXES[name]
    # Adafactor factored second moments inherit the parent param's axes
    if name == "vr":
        return resolve_axes(path[:-1], ndim + 1)[:-1]
    if name == "vc":
        full = resolve_axes(path[:-1], ndim + 1)
        return full[:-2] + full[-1:]
    if name in ("v", "m", "ef") and parents and isinstance(keys[-2], str) \
            and keys[-2] not in _STACK_KEYS:
        # per-param optimizer state dicts ({.../wq/v}); top-level adamw
        # {"m": params...} paths end with the param name instead.
        if keys[-2] in _NAME_AXES or keys[-2] in (
                "wo", "wi", "wi_gate", "norm") or "norm" in str(keys[-2]):
            return resolve_axes(path[:-1], ndim)
    stacked = any(k in _STACK_KEYS for k in parents if isinstance(k, str))
    base_ndim = ndim - 1 if stacked else ndim

    if name in _NAME_AXES:
        axes = _NAME_AXES[name]
    elif name == "wo":
        if base_ndim == 3 and "mlp" in parents:
            axes = ("experts", "mlp", "embed")        # MoE down-proj
        elif base_ndim == 3:
            axes = ("heads", "head_dim", "embed")     # attention out-proj
        elif "mixer" in parents:
            axes = ("ssm_inner", "embed")             # SSD out-proj
        else:
            axes = ("mlp", "embed")                   # dense MLP down-proj
    elif name in ("wi", "wi_gate"):
        axes = (("experts", "embed", "mlp") if base_ndim == 3
                else ("embed", "mlp"))
    elif name == "norm" and "mixer" in parents:
        axes = ("ssm_inner",)                         # SSD gated-norm scale
    elif isinstance(name, str) and "norm" in name:
        axes = ("norm",)
    else:
        axes = (None,) * base_ndim
    if stacked:
        axes = (None,) + tuple(axes)
    assert len(axes) == ndim, (path, axes, ndim)
    return tuple(axes)


def tree_shardings(rules: ShardingRules, shape_tree: Any, mesh: Mesh) -> Any:
    """NamedShardings for a parameter tree via path-based axis resolution."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: rules.sharding_for(resolve_axes(path, len(x.shape)),
                                           x.shape, mesh),
        shape_tree)


def tree_logical_axes(shape_tree: Any) -> Any:
    """The resolved logical-axes tree (for tests / debugging)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: resolve_axes(path, len(x.shape)), shape_tree)


def logical_constraint(rules: ShardingRules, x: jax.Array,
                       logical_axes: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint via logical names (no-op outside jit mesh)."""
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.shape:
        return x
    spec = rules.spec_for(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def _all_auto(m) -> bool:
    try:
        return all("Auto" in str(t) for t in getattr(m, "axis_types", ()))
    except Exception:
        return True


def _manual_axis_names():
    """Mesh axes currently bound by a manual region (shard_map/pmap).

    On old jax (0.4.x) ``axis_types`` does not exist; the bound axis names
    live in the tracing axis env instead."""
    try:
        from jax._src import core as _core
        return tuple(_core.get_axis_env().axis_names())
    except Exception:
        return ()


def get_abstract_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.shape:
            # inside shard_map axes are Manual: constraints must no-op
            return m if _all_auto(m) else None
    except Exception:
        pass
    try:  # legacy `with mesh:` context (thread resources)
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            if any(a in m.shape for a in _manual_axis_names()):
                return None     # inside shard_map over this mesh: no-op
            return m if _all_auto(m) else None
    except Exception:
        pass
    return None
