"""The ten assigned architectures, verbatim from the assignment sheet.

Each entry records the exact published config ([source] in the assignment).
Reduced smoke variants come from :func:`repro.configs.base.smoke_model`.
"""
from __future__ import annotations

from repro.configs.base import LayerSpec, ModelConfig

A = LayerSpec  # shorthand

# jamba-1.5-large-398b [hybrid]: 72L, d=8192, 64H (kv=8), d_ff=24576,
# vocab=65536, MoE 16e top-2, Mamba+attn 1:7 interleave [arXiv:2403.19887].
# Period-8 block: positions 0..7, attention at position 4 (as in Jamba),
# MoE on every odd position (period 2) -> lcm(2,8)=8 block.
_jamba_block = tuple(
    LayerSpec(mixer=("attn" if i == 4 else "mamba"),
              mlp=("moe" if i % 2 == 1 else "dense"))
    for i in range(8)
)

JAMBA = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    pattern=_jamba_block,
    num_experts=16, experts_per_token=2, moe_d_ff=24576,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_conv_kernel=4,
    ssm_groups=1, mlp_gated=True, rope_theta=1e6,
)

# granite-34b [dense]: 88L, d=6144, 48H (kv=1 MQA), d_ff=24576, vocab=49152.
# GPT-BigCode style code model: MQA + non-gated MLP [arXiv:2405.04324].
GRANITE = ModelConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    mlp_gated=False, rope_theta=1e5,
)

# gemma2-27b [dense]: 46L, d=4608, 32H (kv=16), d_ff=36864, vocab=256000.
# Alternating local(4096-window)/global attention, attn softcap 50,
# final-logit softcap 30, post-norms [arXiv:2408.00118].
GEMMA2 = ModelConfig(
    name="gemma2-27b", family="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000,
    pattern=(A(mixer="attn_local"), A(mixer="attn")),
    sliding_window=4096, attn_softcap=50.0, logit_softcap=30.0,
    use_post_norm=True, tie_embeddings=True, scale_embeddings=True,
    mlp_gated=True,
)

# deepseek-67b [dense]: 95L, d=8192, 64H (kv=8), d_ff=22016, vocab=102400.
# Llama architecture [arXiv:2401.02954].
DEEPSEEK = ModelConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102400, mlp_gated=True,
)

# qwen2-1.5b [dense]: 28L, d=1536, 12H (kv=2), d_ff=8960, vocab=151936.
# GQA with QKV bias, tied embeddings [arXiv:2407.10671].
QWEN2 = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, mlp_gated=True, rope_theta=1e6,
)

# phi3.5-moe-42b-a6.6b [moe]: 32L, d=4096, 32H (kv=8), expert d_ff=6400,
# vocab=32064, 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct].
PHI35_MOE = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    pattern=(A(mlp="moe"),),
    num_experts=16, experts_per_token=2, moe_d_ff=6400, mlp_gated=True,
)

# qwen3-moe-235b-a22b [moe]: 94L, d=4096, 64H (kv=4), expert d_ff=1536,
# vocab=151936, 128 experts top-8, qk-norm [hf:Qwen/Qwen3-30B-A3B family].
QWEN3_MOE = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    pattern=(A(mlp="moe"),),
    num_experts=128, experts_per_token=8, moe_d_ff=1536,
    qk_norm=True, mlp_gated=True, rope_theta=1e6,
)

# mamba2-780m [ssm]: 48L, d=1536, attn-free, vocab=50280, ssm_state=128.
# SSD (state-space duality) [arXiv:2405.21060].
MAMBA2 = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    pattern=(A(mixer="mamba", mlp="none"),),
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv_kernel=4,
    ssm_groups=1, tie_embeddings=True,
)

# pixtral-12b [vlm]: 40L, d=5120, 32H (kv=8), d_ff=14336, vocab=131072.
# pixtral-ViT frontend is a STUB (precomputed patch embeddings);
# backbone is mistral-nemo style [hf:mistralai/Pixtral-12B-2409].
PIXTRAL = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072, mlp_gated=True, rope_theta=1e6,
    frontend="patch", frontend_seq=256,
)

# whisper-small [audio]: 12L enc + 12L dec, d=768, 12H (MHA), d_ff=3072,
# vocab=51865. Conv frontend is a STUB (precomputed frame embeddings)
# [arXiv:2212.04356].
WHISPER = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    mlp_gated=False, encoder_layers=12, encoder_seq=1500,
    frontend="audio", pos_embedding="learned", tie_embeddings=True,
)

ARCHS: dict[str, ModelConfig] = {
    "jamba-1.5-large-398b": JAMBA,
    "granite-34b": GRANITE,
    "gemma2-27b": GEMMA2,
    "deepseek-67b": DEEPSEEK,
    "qwen2-1.5b": QWEN2,
    "phi3.5-moe-42b-a6.6b": PHI35_MOE,
    "qwen3-moe-235b-a22b": QWEN3_MOE,
    "mamba2-780m": MAMBA2,
    "pixtral-12b": PIXTRAL,
    "whisper-small": WHISPER,
}

# long_500k requires sub-quadratic attention; the memory-feasible decoders
# are the SSM/hybrid archs + gemma2 (alternating local windows; SP-sharded
# global cache fits).  Pure full-attention archs skip (see DESIGN.md §5).
LONG_CONTEXT_OK = {"jamba-1.5-large-398b", "mamba2-780m", "gemma2-27b"}


def skip_reason(arch: str, shape: str) -> str | None:
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK:
        return "full-attention arch: 500k decode cache infeasible (DESIGN §5)"
    return None
