"""Config system.

A :class:`ModelConfig` fully describes one architecture (the ten assigned
archs + the paper's graph-engine workload use these).  A :class:`RunConfig`
binds a model to a mesh / shape / dtype / optimizer choice.  Configs are
plain frozen dataclasses: hashable, printable, diffable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# Layer pattern: the repeating block of a (possibly heterogeneous) stack.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer position inside the repeating block.

    mixer: "attn" | "attn_local" | "mamba"
    mlp:   "dense" | "moe" | "none"
    """
    mixer: str = "attn"
    mlp: str = "dense"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # repeating layer pattern; len(pattern) must divide num_layers.
    pattern: Sequence[LayerSpec] = (LayerSpec(),)

    # --- attention details ---
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: Optional[float] = None     # gemma2: 50.0
    logit_softcap: Optional[float] = None    # gemma2: 30.0
    sliding_window: Optional[int] = None     # window for "attn_local" mixers
    rope_theta: float = 10000.0
    pos_embedding: str = "rope"              # "rope" | "learned" | "none"
    max_position: int = 0                    # learned-pos table size (0=auto)
    use_post_norm: bool = False              # gemma2 post-layer norms

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_kernel: int = 4
    ssm_groups: int = 1

    # --- MLP style ---
    mlp_gated: bool = True                   # llama-style SwiGLU vs plain GELU

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0                  # >0 => encoder-decoder
    encoder_seq: int = 0                     # stub frontend sequence length

    # --- modality frontend stubs ---
    frontend: Optional[str] = None           # "patch" | "audio" | None
    frontend_seq: int = 0                    # extra prefix embeddings per seq

    # --- misc ---
    tie_embeddings: bool = False
    scale_embeddings: bool = False           # gemma-style sqrt(d) embed scale
    norm_eps: float = 1e-6
    vocab_pad_to: int = 256
    # attention implementation: chunked flash path beyond this many kv tokens
    attn_chunk: int = 2048

    # ---- derived ----
    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def d_inner(self) -> int:                # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def full_pattern(self) -> Sequence[LayerSpec]:
        assert self.num_layers % len(self.pattern) == 0, (
            f"{self.name}: pattern {len(self.pattern)} !| {self.num_layers}")
        return tuple(self.pattern)

    @property
    def num_blocks(self) -> int:
        return self.num_layers // len(self.pattern)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model FLOPs)."""
        d, v = self.d_model, self.padded_vocab
        n = v * d
        if not self.tie_embeddings:
            n += v * d
        n += self.num_blocks * sum(
            self._layer_params(spec) for spec in self.full_pattern)
        if self.encoder_layers:
            n += self.encoder_layers * self._layer_params(
                LayerSpec("attn", "dense"))
            # decoder cross-attention blocks (+ their norms)
            n += self.num_layers * (self._attn_params() + self.d_model)
        return n

    def _attn_params(self) -> int:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        p = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.qkv_bias:
            p += (h + 2 * kv) * hd
        return p

    def _layer_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        n = 2 * d  # norms
        if spec.mixer in ("attn", "attn_local"):
            n += self._attn_params()
        elif spec.mixer == "mamba":
            din, st, g, nh = (self.d_inner, self.ssm_state, self.ssm_groups,
                              self.ssm_heads)
            n += d * (2 * din + 2 * g * st + nh)      # in_proj
            n += self.ssm_conv_kernel * (din + 2 * g * st)  # conv
            n += din * d                              # out_proj
            n += 3 * nh                               # A, D, dt_bias
        if spec.mlp == "dense":
            mult = 3 if self.mlp_gated else 2
            n += mult * d * self.d_ff
        elif spec.mlp == "moe":
            mult = 3 if self.mlp_gated else 2
            n += self.num_experts * mult * d * self.moe_d_ff
            n += d * self.num_experts                 # router
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        d = self.d_model
        n = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        for spec in self.full_pattern:
            ln = self._layer_params(spec)
            if spec.mlp == "moe":
                mult = 3 if self.mlp_gated else 2
                ln -= self.num_experts * mult * d * self.moe_d_ff
                ln += self.experts_per_token * mult * d * self.moe_d_ff
            n += self.num_blocks * ln
        return n


# ---------------------------------------------------------------------------
# Input shapes (the assigned 4 shapes) and run configuration.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    # mesh
    multi_pod: bool = False
    # numerics
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    # optimizer: "adamw" | "adafactor"
    optimizer: str = "adamw"
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # remat: "none" | "full" | "dots"
    remat: str = "full"
    microbatches: int = 1
    # AAM / MoE path: "dense" (one-hot baseline) | "aam" (sorted+coalesced)
    moe_impl: str = "aam"
    # prefill/train flash attention: unrolled causal-prefix kv scan (§Perf)
    attn_causal_skip: bool = False
    # pin grads/accumulators to param sharding (reduce-scatter not
    # all-reduce; §Perf iteration "shard-grads")
    shard_grads: bool = False
    # serving weight layout: TP-only bf16, no FSDP gathers (§Perf "serve-tp")
    serve_tp: bool = False
    # sequence parallelism for dense-attention stacks: residual stream
    # seq-sharded over 'model'; only grouped K/V gathers (§Perf "seqp")
    seq_parallel: bool = False
    use_pallas: bool = False   # enable TPU Pallas kernels (off on CPU)
    # gradient compression across pods ("none" | "int8_ef")
    grad_compression: str = "none"
    seed: int = 0


def smoke_model(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to a CPU-runnable smoke variant of the same family."""
    pat = cfg.full_pattern
    # keep one full pattern block (preserves heterogeneity)
    num_layers = len(pat)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=num_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        vocab_size=503,          # deliberately ragged to exercise padding
        vocab_pad_to=64,
        sliding_window=32 if cfg.sliding_window else None,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=24 if cfg.encoder_seq else 0,
        frontend_seq=8 if cfg.frontend_seq else 0,
        attn_chunk=64,
    )
