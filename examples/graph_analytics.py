"""All six paper case-studies (§3.3) on the AAM engine, with telemetry.

  PYTHONPATH=src python examples/graph_analytics.py
"""
import time

import numpy as np

from repro.core.commit import CommitSpec
from repro.graphs.generators import (erdos_renyi, grid2d, kronecker,
                                     random_weights)
from repro.graphs.algorithms.bfs import bfs
from repro.graphs.algorithms.boruvka import boruvka, mst_reference
from repro.graphs.algorithms.coloring import coloring, validate_coloring
from repro.graphs.algorithms.pagerank import pagerank
from repro.graphs.algorithms.sssp import sssp
from repro.graphs.algorithms.stconn import st_connectivity

g = kronecker(scale=13, edge_factor=16, seed=1)
gw = random_weights(g, seed=2)
src = int(np.argmax(np.asarray(g.degrees)))
far = int(np.argsort(np.asarray(g.degrees))[-2])
print(f"Kronecker graph |V|={g.num_vertices} |E|={g.num_edges}\n")


def run(name, msg_type, fn):
    t0 = time.perf_counter()
    out = fn()
    dt = time.perf_counter() - t0
    print(f"{name:18s} [{msg_type}]  {dt*1e3:8.1f} ms   {out}")


run("BFS", "FF&MF", lambda: (lambda r:
    f"rounds={int(r.rounds)} conflicts={int(r.conflicts)}")(
    bfs(g, src, spec=CommitSpec(backend="coarse", m=4096, stats=False))))
run("PageRank", "FF&AS", lambda: (lambda r:
    f"sum={float(r[0].sum()):.4f} conflicting-accs={int(r[1])}")(
    pagerank(g, iters=20)))
run("SSSP", "FF&MF", lambda: (lambda d, rr:
    f"rounds={int(rr)} reached={int((d < 1e38).sum())}")(
    *sssp(gw, src)))
run("ST-connectivity", "FR&AS", lambda: (lambda f, r:
    f"connected={bool(f)} rounds={int(r)}")(
    *st_connectivity(g, src, far)))
run("Boman coloring", "FR&MF", lambda: (lambda c, r, failed:
    f"colors={int(np.asarray(c).max())+1} rounds={int(r)} "
    f"valid={validate_coloring(g, c)}")(
    *coloring(g, seed=0)))
gw_small = random_weights(erdos_renyi(2000, 8.0, seed=3), seed=4)
run("Boruvka MST", "FR&MF", lambda: (lambda comp, w, ne, r:
    f"weight={float(w):.1f} (ref {mst_reference(gw_small):.1f}) "
    f"edges={int(ne)} rounds={int(r)}")(
    *boruvka(gw_small)))
