"""All six paper case-studies (§3.3) on the AAM engine, with telemetry.

  PYTHONPATH=src python examples/graph_analytics.py
  PYTHONPATH=src python examples/graph_analytics.py --distributed
    # re-execs with 8 forced host devices and additionally runs all six
    # algorithms through the shared run_distributed harness (§6.2)
"""
import os
import subprocess
import sys
import time

import numpy as np

DISTRIBUTED = "--distributed" in sys.argv
if DISTRIBUTED and os.environ.get("_REPRO_CHILD") != "1":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["_REPRO_CHILD"] = "1"
    raise SystemExit(subprocess.run([sys.executable] + sys.argv,
                                    env=env).returncode)

from repro.core.commit import CommitSpec
from repro.graphs.generators import (erdos_renyi, grid2d, kronecker,
                                     random_weights)
from repro.graphs.algorithms.bfs import bfs
from repro.graphs.algorithms.boruvka import boruvka, mst_reference
from repro.graphs.algorithms.coloring import coloring, validate_coloring
from repro.graphs.algorithms.pagerank import pagerank
from repro.graphs.algorithms.sssp import sssp
from repro.graphs.algorithms.stconn import st_connectivity

g = kronecker(scale=13, edge_factor=16, seed=1)
gw = random_weights(g, seed=2)
src = int(np.argmax(np.asarray(g.degrees)))
far = int(np.argsort(np.asarray(g.degrees))[-2])
print(f"Kronecker graph |V|={g.num_vertices} |E|={g.num_edges}\n")


def run(name, msg_type, fn):
    t0 = time.perf_counter()
    out = fn()
    dt = time.perf_counter() - t0
    print(f"{name:18s} [{msg_type}]  {dt*1e3:8.1f} ms   {out}")


run("BFS", "FF&MF", lambda: (lambda r:
    f"rounds={int(r.rounds)} conflicts={int(r.conflicts)}")(
    bfs(g, src, spec=CommitSpec(backend="coarse", m=4096, stats=False))))
run("BFS (auto-tuned)", "FF&MF", lambda: (lambda r:
    f"rounds={int(r.rounds)} conflicts={int(r.conflicts)} "
    f"(calibrated backend+M, conflict-feedback sizing)")(
    bfs(g, src, spec=CommitSpec(backend="auto", stats=False))))
run("PageRank", "FF&AS", lambda: (lambda r:
    f"sum={float(r[0].sum()):.4f} conflicting-accs={int(r[1])}")(
    pagerank(g, iters=20)))
run("SSSP", "FF&MF", lambda: (lambda d, rr:
    f"rounds={int(rr)} reached={int((d < 1e38).sum())}")(
    *sssp(gw, src)))
run("ST-connectivity", "FR&AS", lambda: (lambda f, r:
    f"connected={bool(f)} rounds={int(r)}")(
    *st_connectivity(g, src, far)))
run("Boman coloring", "FR&MF", lambda: (lambda c, r, failed:
    f"colors={int(np.asarray(c).max())+1} rounds={int(r)} "
    f"valid={validate_coloring(g, c)}")(
    *coloring(g, seed=0)))
gw_small = random_weights(erdos_renyi(2000, 8.0, seed=3), seed=4)
run("Boruvka MST", "FR&MF", lambda: (lambda comp, w, ne, r:
    f"weight={float(w):.1f} (ref {mst_reference(gw_small):.1f}) "
    f"edges={int(ne)} rounds={int(r)}")(
    *boruvka(gw_small)))

if DISTRIBUTED:
    from repro.launch.mesh import make_host_mesh
    from repro.graphs.algorithms.bfs import distributed_bfs
    from repro.graphs.algorithms.boruvka import distributed_boruvka
    from repro.graphs.algorithms.coloring import distributed_coloring
    from repro.graphs.algorithms.pagerank import distributed_pagerank
    from repro.graphs.algorithms.sssp import distributed_sssp
    from repro.graphs.algorithms.stconn import distributed_stconn

    mesh = make_host_mesh(8, 1)
    gd = kronecker(scale=10, edge_factor=8, seed=1)
    gdw = random_weights(gd, seed=2)
    sd = int(np.argmax(np.asarray(gd.degrees)))
    fd = int(np.argsort(np.asarray(gd.degrees))[-2])
    print(f"\n8-shard run_distributed harness; "
          f"|V|={gd.num_vertices} |E|={gd.num_edges}")

    def rund(name, msg_type, fn):
        t0 = time.perf_counter()
        out, res = fn()
        dt = time.perf_counter() - t0
        print(f"{name:18s} [{msg_type}]  {dt*1e3:8.1f} ms   {out}  "
              f"rounds={int(res.rounds)} conflicts={int(res.conflicts)} "
              f"subrounds={int(res.subrounds)} "
              f"delivered_all={bool(res.delivered_all)}")

    rund("BFS", "FF&MF", lambda: (lambda d, ro, r:
        (f"reached={int((np.asarray(d) < 2**30).sum())}", r))(
        *distributed_bfs(mesh, gd, sd, capacity=2048, telemetry=True)))
    rund("PageRank", "FF&AS", lambda: (lambda pr, r:
        (f"sum={float(pr.sum()):.4f}", r))(
        *distributed_pagerank(mesh, gd, iters=10, capacity=2048,
                              telemetry=True)))
    rund("SSSP", "FF&MF", lambda: (lambda d, ro, r:
        (f"reached={int((np.asarray(d) < 1e38).sum())}", r))(
        *distributed_sssp(mesh, gdw, sd, capacity=2048, telemetry=True)))
    rund("ST-connectivity", "FR&AS", lambda: (lambda f, ro, r:
        (f"connected={bool(f)}", r))(
        *distributed_stconn(mesh, gd, sd, fd, capacity=2048,
                            telemetry=True)))
    rund("Boman coloring", "FR&MF", lambda: (lambda c, ro, nc, r:
        (f"colors={int(np.asarray(c).max())+1} "
         f"valid={validate_coloring(gd, c)}", r))(
        *distributed_coloring(mesh, gd, seed=0, capacity=2048,
                              telemetry=True)))
    rund("Boruvka MST", "FR&MF", lambda: (lambda comp, w, ne, ro, r:
        (f"weight={float(w):.1f} edges={int(ne)}", r))(
        *distributed_boruvka(mesh, gdw, capacity=2048, telemetry=True)))
