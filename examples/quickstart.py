"""Quickstart: Atomic Active Messages in 60 seconds.

1. Commit one batch of messages through every backend of the unified
   ``commit()`` API — same semantics, interchangeable mechanisms.
2. Build a Graph500 Kronecker graph; run BFS with fine-grained atomics vs
   coarse AAM transactions vs the Pallas kernel.
3. Run PageRank on the Always-Succeed accumulate commit.
4. Inspect the conflict telemetry (the paper's abort statistics analogue).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.commit import BACKENDS, CommitSpec, commit
from repro.core.messages import make_messages
from repro.graphs.generators import kronecker
from repro.graphs.algorithms.bfs import bfs, bfs_reference
from repro.graphs.algorithms.pagerank import pagerank, pagerank_reference

# --- one semantic op, three mechanisms ----------------------------------
state = jnp.full((8,), 100, jnp.int32)
msgs = make_messages(jnp.asarray([3, 3, 5], jnp.int32),
                     jnp.asarray([7, 9, 1], jnp.int32))
for backend in BACKENDS:                         # atomic | coarse | pallas
    res = commit(state, msgs, "min", CommitSpec(backend=backend, m=2))
    print(f"commit[{backend:6s}] state={np.asarray(res.state)} "
          f"success={np.asarray(res.success)}")

g = kronecker(scale=12, edge_factor=16, seed=0)
print(f"\ngraph: |V|={g.num_vertices} |E|={g.num_edges} "
      f"d̄={g.avg_degree:.1f} (power-law)")

src = int(np.argmax(np.asarray(g.degrees)))

# --- BFS: FF&MF messages, min-commit ------------------------------------
r_atomic = bfs(g, src, spec=CommitSpec(backend="atomic", stats=False))
r_aam = bfs(g, src,                              # AAM: 4096-message txns
            spec=CommitSpec(backend="coarse", m=4096, stats=False))
# backend="auto": online calibration picks backend + M*, then the conflict
# telemetry adapts M round-to-round (README "Auto-tuned commits")
r_auto = bfs(g, src, spec=CommitSpec(backend="auto", stats=False))
ref = bfs_reference(g, src)
assert np.array_equal(np.asarray(r_atomic.dist, np.int64), ref)
assert np.array_equal(np.asarray(r_aam.dist, np.int64), ref)
assert np.array_equal(np.asarray(r_auto.dist, np.int64), ref)
print(f"BFS    rounds={int(r_aam.rounds)} messages={int(r_aam.messages)} "
      f"conflicts={int(r_aam.conflicts)} "
      f"(duplicate-target messages resolved on-chip, zero aborts)")

# --- PageRank: FF&AS messages, accumulate-commit -------------------------
rank, conflicts = pagerank(g, iters=20)
err = float(np.abs(np.asarray(rank) - pagerank_reference(g, iters=20)).max())
print(f"PR     sum={float(rank.sum()):.6f} max|err|={err:.2e} "
      f"conflicting-accumulates={int(conflicts)} (all committed, "
      f"serialization-free)")
print("OK — see examples/graph_analytics.py and examples/train_lm.py next.")
