"""Quickstart: Atomic Active Messages in 60 seconds.

1. Build a Graph500 Kronecker graph.
2. Run BFS with fine-grained atomics vs coarse AAM transactions.
3. Run PageRank on the Always-Succeed accumulate commit.
4. Inspect the conflict telemetry (the paper's abort statistics analogue).

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.graphs.generators import kronecker
from repro.graphs.algorithms.bfs import bfs, bfs_reference
from repro.graphs.algorithms.pagerank import pagerank, pagerank_reference

g = kronecker(scale=12, edge_factor=16, seed=0)
print(f"graph: |V|={g.num_vertices} |E|={g.num_edges} "
      f"d̄={g.avg_degree:.1f} (power-law)")

src = int(np.argmax(np.asarray(g.degrees)))

# --- BFS: FF&MF messages, min-commit ------------------------------------
r_atomic = bfs(g, src, commit="atomic")          # fine-grained baseline
r_aam = bfs(g, src, commit="coarse", m=4096)     # AAM: 4096-message txns
ref = bfs_reference(g, src)
assert np.array_equal(np.asarray(r_atomic.dist, np.int64), ref)
assert np.array_equal(np.asarray(r_aam.dist, np.int64), ref)
print(f"BFS    rounds={int(r_aam.rounds)} messages={int(r_aam.messages)} "
      f"conflicts={int(r_aam.conflicts)} "
      f"(duplicate-target messages resolved on-chip, zero aborts)")

# --- PageRank: FF&AS messages, accumulate-commit -------------------------
rank, conflicts = pagerank(g, iters=20)
err = float(np.abs(np.asarray(rank) - pagerank_reference(g, iters=20)).max())
print(f"PR     sum={float(rank.sum()):.6f} max|err|={err:.2e} "
      f"conflicting-accumulates={int(conflicts)} (all committed, "
      f"serialization-free)")
print("OK — see examples/graph_analytics.py and examples/train_lm.py next.")
