"""Distributed PageRank + BFS over 8 shards (the paper's §6.2 scenario):
coalesced accumulate waves over all_to_all, with sub-round requeue.

Re-execs itself with 8 forced host devices.

  PYTHONPATH=src python examples/distributed_pagerank.py
"""
import os
import subprocess
import sys

if os.environ.get("_REPRO_CHILD") != "1":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["_REPRO_CHILD"] = "1"
    raise SystemExit(subprocess.run([sys.executable] + sys.argv,
                                    env=env).returncode)

import time

import numpy as np

from repro.core.engine import distributed_bfs, distributed_pagerank
from repro.graphs.algorithms.bfs import bfs_reference
from repro.graphs.algorithms.pagerank import pagerank_reference
from repro.graphs.generators import kronecker
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(8, 1)
g = kronecker(scale=13, edge_factor=8, seed=5)
src = int(np.argmax(np.asarray(g.degrees)))
print(f"8-shard mesh; graph |V|={g.num_vertices} |E|={g.num_edges}")

t0 = time.perf_counter()
dist, rounds = distributed_bfs(mesh, g, src, capacity=8192)
dt = time.perf_counter() - t0
ok = np.array_equal(np.asarray(dist, np.int64), bfs_reference(g, src))
print(f"distributed BFS : {dt*1e3:7.1f} ms rounds={int(rounds)} "
      f"correct={ok}")

t0 = time.perf_counter()
pr = distributed_pagerank(mesh, g, iters=10, capacity=8192)
dt = time.perf_counter() - t0
err = float(np.abs(np.asarray(pr) - pagerank_reference(g, iters=10)).max())
print(f"distributed PR  : {dt*1e3:7.1f} ms max|err|={err:.2e}")
