"""Serving graph queries — GraphService quickstart (ISSUE 4).

Many independent user queries (BFS sources, SSSP roots, personalized
PageRank seeds, s-t connectivity pairs) fuse into lanes of ONE AAM wave:
composite commit keys ``lane * V + v`` let a single conflict-resolution
pass serve every query at once, and the service pads lane counts up a
power-of-two ladder so the jit caches stay warm.

  PYTHONPATH=src python examples/serve_queries.py
"""
import time

import numpy as np

from repro.graphs.generators import kronecker, random_weights
from repro.serve.graph_service import GraphService
from repro.serve.queries import (BfsQuery, PprQuery, SsspQuery,
                                 StConnQuery)

# --- construction: one service, two tenant graphs --------------------------
g = kronecker(scale=9, edge_factor=8, seed=1)
gw = random_weights(g, seed=2)
svc = GraphService(max_lanes=8)          # default spec: calibrated "auto"
svc.register_graph("social", g)
svc.register_graph("roads", gw)
print(f"graph |V|={g.num_vertices} |E|={g.num_edges}; "
      f"lane ladder {svc.lane_ladder}\n")

# --- submit: a mixed stream of queries -------------------------------------
rng = np.random.default_rng(0)
sources = rng.choice(g.num_vertices, 8, replace=False)
tickets = [svc.submit("social", BfsQuery(int(s))) for s in sources[:5]]
tickets += [svc.submit("social", PprQuery(int(sources[5]), iters=10)),
            svc.submit("roads", SsspQuery(int(sources[6]))),
            svc.submit("social", StConnQuery(int(sources[0]),
                                             int(sources[7])))]
print(f"submitted {svc.stats.submitted} queries -> "
      f"{svc.pending()} distinct pending")

# --- drain: fused lane waves -----------------------------------------------
t0 = time.perf_counter()
done = svc.drain()
dt = time.perf_counter() - t0
print(f"drained {len(done)} tickets in {dt * 1e3:.1f} ms over "
      f"{svc.stats.waves} fused waves "
      f"({svc.stats.lanes_executed} lanes, "
      f"{svc.stats.lanes_padded} ladder padding)\n")

dist = svc.result(tickets[0])
print(f"BFS from {int(sources[0])}: "
      f"reached {int((np.asarray(dist) < 2 ** 30).sum())} vertices")
rank = svc.result(tickets[5])
print(f"PPR from {int(sources[5])}: top vertex "
      f"{int(np.argmax(np.asarray(rank)))}, mass "
      f"{float(np.asarray(rank).sum()):.4f}")
print(f"s-t connected({int(sources[0])}, {int(sources[7])}): "
      f"{svc.result(tickets[7])}")

# --- the cache: a repeat visitor costs nothing -----------------------------
t = svc.submit("social", BfsQuery(int(sources[0])))
assert np.array_equal(np.asarray(svc.result(t)), np.asarray(dist))
print(f"\nrepeat query served from cache "
      f"(cache_hits={svc.stats.cache_hits}, no new wave: "
      f"waves={svc.stats.waves})")
