"""Serving graph queries — GraphService quickstart (ISSUE 4 + 5 + 7).

Many independent user queries fuse into ONE AAM wave along whichever
batch axis fits: same-graph queries (BFS sources, SSSP roots,
personalized PageRank seeds, s-t pairs) as lanes on composite commit
keys ``lane * V + v``; same-kind queries across tenant graphs —
including the whole-graph kinds, coloring and Boruvka MST, which have
no lane form — as a graph batch on the tenants' disjoint-union key
space; MIXED same-kind traffic as one lanes×graphs PRODUCT wave on
keys ``lane * Vtot + offset[g] + v``.  The service picks the axis at
drain time and pads each axis up its own power-of-two ladder so the
jit caches stay warm.  The final stanza serves asynchronously: a
ContinuousServer drain loop admits on a deadline window and boards
late arrivals onto the running product wave.

  PYTHONPATH=src python examples/serve_queries.py
"""
import time

import numpy as np

from repro.graphs.generators import kronecker, random_weights
from repro.serve.graph_service import GraphService
from repro.serve.queries import (BfsQuery, ColoringQuery, MstQuery,
                                 PprQuery, SsspQuery, StConnQuery)

# --- construction: one service, two tenant graphs --------------------------
g = kronecker(scale=9, edge_factor=8, seed=1)
gw = random_weights(g, seed=2)
svc = GraphService(max_lanes=8)          # default spec: calibrated "auto"
svc.register_graph("social", g)
svc.register_graph("roads", gw)
print(f"graph |V|={g.num_vertices} |E|={g.num_edges}; "
      f"lane ladder {svc.lane_ladder}\n")

# --- submit: a mixed stream of queries -------------------------------------
rng = np.random.default_rng(0)
sources = rng.choice(g.num_vertices, 8, replace=False)
tickets = [svc.submit("social", BfsQuery(int(s))) for s in sources[:5]]
tickets += [svc.submit("social", PprQuery(int(sources[5]), iters=10)),
            svc.submit("roads", SsspQuery(int(sources[6]))),
            svc.submit("social", StConnQuery(int(sources[0]),
                                             int(sources[7])))]
print(f"submitted {svc.stats.submitted} queries -> "
      f"{svc.pending()} distinct pending")

# --- drain: fused lane waves -----------------------------------------------
t0 = time.perf_counter()
done = svc.drain()
dt = time.perf_counter() - t0
print(f"drained {len(done)} tickets in {dt * 1e3:.1f} ms over "
      f"{svc.stats.waves} fused waves "
      f"({svc.stats.lanes_executed} lanes, "
      f"{svc.stats.lanes_padded} ladder padding)\n")

dist = svc.result(tickets[0])
print(f"BFS from {int(sources[0])}: "
      f"reached {int((np.asarray(dist) < 2 ** 30).sum())} vertices")
rank = svc.result(tickets[5])
print(f"PPR from {int(sources[5])}: top vertex "
      f"{int(np.argmax(np.asarray(rank)))}, mass "
      f"{float(np.asarray(rank).sum()):.4f}")
print(f"s-t connected({int(sources[0])}, {int(sources[7])}): "
      f"{svc.result(tickets[7])}")

# --- the cache: a repeat visitor costs nothing -----------------------------
t = svc.submit("social", BfsQuery(int(sources[0])))
assert np.array_equal(np.asarray(svc.result(t)), np.asarray(dist))
print(f"\nrepeat query served from cache "
      f"(cache_hits={svc.stats.cache_hits}, no new wave: "
      f"waves={svc.stats.waves})")

# --- mixed tenants: the GRAPH batch axis -----------------------------------
# Six more tenant graphs, one query each: single-query tenants fuse
# ACROSS graphs (one wave over the disjoint union) instead of one wave
# per tenant — and whole-graph queries (coloring, MST) become servable,
# since independent graphs trivially share a wave.
for i in range(6):
    svc.register_graph(f"tenant{i}", kronecker(scale=8 - (i % 2),
                                               edge_factor=6, seed=10 + i))
gw0 = svc.stats.graph_waves
tickets = [svc.submit(f"tenant{i}", BfsQuery(0)) for i in range(6)]
tickets += [svc.submit(f"tenant{i}", ColoringQuery()) for i in range(6)]
tickets.append(svc.submit("tenant0", MstQuery()))
t0 = time.perf_counter()
svc.drain()
dt = time.perf_counter() - t0
print(f"\nmixed tenants: drained {len(tickets)} single-query tenants in "
      f"{dt * 1e3:.1f} ms over {svc.stats.graph_waves - gw0} graph-batch "
      f"waves ({svc.stats.graphs_batched} graphs incl. "
      f"{svc.stats.graphs_padded} ladder padding)")
colors = svc.result(tickets[6])
print(f"tenant0 coloring: {int(np.asarray(colors).max()) + 1} colors")
comp, weight, n_edges = svc.result(tickets[-1])
print(f"tenant0 MST: {int(n_edges)} edges, weight {float(weight):.1f}")

# --- durability: kill the service mid-drain, restore, finish ---------------
# A ServiceSupervisor wraps the service with a snapshot Checkpointer plus
# a submit journal (WAL): acknowledged tickets survive a host loss even
# if no snapshot ran since.  The snapshot carries the learned autotune
# entries and ladder M levels, so the restored service is WARM — it
# re-serves without a single re-calibration timing run.
import shutil
import tempfile

from repro.checkpoint.checkpointer import Checkpointer
from repro.serve.durable import ServiceSupervisor

ckdir = tempfile.mkdtemp(prefix="svc_ck_")
sup = ServiceSupervisor(svc, Checkpointer(ckdir), log=lambda *_: None)
sup.save()                               # warm snapshot (results + tuner)
tickets = [sup.submit("social", BfsQuery(int(s))) for s in sources[2:7]]

# simulate the host dying on the drain's first fused wave
kill_wave = svc._wave_i
svc.fault_injector = (
    lambda where, i: (_ for _ in ()).throw(RuntimeError("host lost"))
    if i == kill_wave else None)
t0 = time.perf_counter()
done = sup.drain()                       # crash -> restore -> re-drain
dt = time.perf_counter() - t0
svc = sup.service                        # the restored instance
rows = [sup.result(t) for t in tickets]  # every acknowledged ticket answered
from repro.graphs.algorithms.bfs import bfs as _bfs
assert all(np.array_equal(np.asarray(r), np.asarray(_bfs(g, int(s)).dist))
           for r, s in zip(rows, sources[2:7]))
print(f"\nkilled wave {kill_wave}, supervisor restored snapshot + WAL and "
      f"finished {len(rows)} tickets in {dt * 1e3:.1f} ms "
      f"(restarts={sup.restarts}, "
      f"post-restore timing runs={svc.stats.timing_runs})")

# --- continuous batching: async submits board the running wave -------------
# ContinuousServer runs drain() on a background thread behind a deadline
# admission window; submit() is non-blocking and late arrivals claim free
# cells of the RUNNING lanes×graphs product wave instead of waiting for
# the next drain.  Wrapping the supervisor keeps the WAL journaling, so
# an async crash mid-wave restores and still answers every ticket.
from repro.serve.continuous import ContinuousServer

fresh = rng.choice(g.num_vertices, 4, replace=False)
with ContinuousServer(sup, max_wait_s=0.01) as cs:
    hot = [cs.submit("social", BfsQuery(int(s))) for s in fresh[:3]]
    tail = [cs.submit(f"tenant{i}", BfsQuery(1)) for i in range(3)]
    late = cs.submit("social", BfsQuery(int(fresh[3])))  # boards mid-wave
    rows = cs.results(hot + tail + [late], timeout=120)
svc = sup.service
lat = sorted((cs.done_at[t] - cs.submit_at[t]) * 1e3
             for t in hot + tail + [late])
print(f"\ncontinuous batching: {len(rows)} async tickets over "
      f"{svc.stats.product_waves} product wave(s) "
      f"({svc.stats.product_cells} cells, "
      f"{svc.stats.product_cells_padded} padded); "
      f"latency p50={lat[len(lat) // 2]:.1f}ms max={lat[-1]:.1f}ms")
shutil.rmtree(ckdir, ignore_errors=True)

# --- observability: trace one traced drain, export everything --------------
# Wavescope (repro.obs) has three layers: a span Tracer on the serving
# path (submit/admit/drain/wave spans, restore/WAL-replay instants), an
# io_callback wave tap INSIDE the jitted round loops (per-round
# conflicts, commit density, ladder level — only planted when tracing is
# on; `aamlint --trace-off-clean` proves the jaxprs are clean
# otherwise), and the metrics registry behind svc.stats (Prometheus
# text + aam-metrics/v1 JSON, incl. the continuous server's
# submit-to-answer latency histogram).  REPRO_TRACE=1 turns all of it
# on globally; here we scope it to one service instead.
import dataclasses
import json

from repro.obs import trace as OT
from repro.obs import wavetap as OW

tracer = OT.Tracer(enabled=True)
svc2 = GraphService(tracer=tracer,
                    spec=dataclasses.replace(svc.spec, trace=True))
svc2.register_graph("social", g)
for s in sources[:4]:
    svc2.submit("social", BfsQuery(int(s)))
OW.clear()
svc2.drain()
OW.flush_to(tracer)                      # device-tid wave events
doc = tracer.to_chrome()
assert not OT.validate_trace(doc) and not tracer.open_spans()
with open("TRACE_example.json", "w") as f:
    json.dump(doc, f)
spans = [e["name"] for e in doc["traceEvents"] if e["ph"] == "X"]
print(f"\nwavescope: {len(doc['traceEvents'])} trace events "
      f"({', '.join(sorted(set(spans))[:4])}, ...) -> TRACE_example.json "
      f"(open in https://ui.perfetto.dev)")
print("registry snapshot: "
      f"{svc2.stats.total_waves} total waves; prometheus text "
      f"{len(svc2.stats.registry.prometheus_text().splitlines())} lines "
      f"(see `make trace` for the mixed-tenant continuous demo)")
