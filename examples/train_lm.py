"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Full production path on CPU: sharded params, jit train step, async
checkpoints, straggler watchdog, exact resume.  ~15 min on one CPU core for
the default 300 steps; pass --steps 50 for a quick look.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from repro.configs import archs
from repro.configs.base import ModelConfig

# ~103M params: qwen2-style dense decoder
LM100M = ModelConfig(
    name="lm-100m", family="dense",
    num_layers=10, d_model=640, num_heads=10, num_kv_heads=2, head_dim=64,
    d_ff=2560, vocab_size=32000, tie_embeddings=True, mlp_gated=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    print(f"params: {LM100M.param_count()/1e6:.1f}M")
    archs.ARCHS["lm-100m"] = LM100M      # register for the launcher
    from repro.launch import train as T
    sys.argv = ["train", "--arch", "lm-100m", "--steps", str(args.steps),
                "--batch", str(args.batch), "--seq", str(args.seq),
                "--ckpt-dir", args.ckpt_dir, "--lr", "6e-4",
                "--save-every", "100"]
    T.main()


if __name__ == "__main__":
    main()
