# Test tiers (see README "Tests").
#
#   make tier1   fast pre-commit loop: everything except the subprocess-
#                spawning distributed/system tests
#   make tier2   the `slow` 8-device subprocess suite under a FIXED XLA
#                flag matrix: every child inherits the same deterministic
#                flags (REPRO_XLA_EXTRA is appended to each child's
#                XLA_FLAGS by tests/test_distributed.py::run_devices), so
#                tier2 failures reproduce run-to-run
#   make test    both tiers
PY ?= python
export PYTHONPATH := src

TIER2_XLA := --xla_cpu_multi_thread_eigen=false
TIER2_ENV := REPRO_XLA_EXTRA="$(TIER2_XLA)" PYTHONHASHSEED=0

# Which trajectory file the bench targets write (one per PR so the
# auto-diff in benchmarks.run compares against the previous PR's rows):
#   make bench-json BENCH=BENCH_pr11.json
BENCH ?= BENCH_pr10.json

.PHONY: tier1 tier2 test lint bench bench-json bench-serve bench-crash \
	bench-latency trace

tier1:
	$(PY) -m pytest -x -q -m "not slow"

# aamlint: op-algebra + key-space + jaxpr wave-race passes over all six
# algorithms x batch-axis kinds, plus the BENCH_*.json schema check
# (exits nonzero on findings; see README "Static analysis & sanitizers")
lint:
	$(PY) -m repro.analysis.lint --bench-schema --trace-off-clean

tier2:
	$(TIER2_ENV) $(PY) -m pytest -q -m slow

test: tier1 tier2

bench:
	$(PY) -m benchmarks.run

# the persistent perf trajectory: tiny fig3/fig4/fig6/fig7/serve sweeps x
# every backend x the calibrated auto spec (schema checked by
# tests/test_autotune.py), auto-diffed against the most recent previous
# BENCH_*.json; serve rows cover BOTH batch axes (L= lanes, G= graphs)
bench-json:
	$(PY) -m benchmarks.run --json $(BENCH) --sizes tiny

# serving throughput/latency: batch-axis GraphService QPS + p50/p99 vs
# the sequential query-at-a-time loop (lane axis by default; add
# `--axis graphs` for the tenant-graph axis)
bench-serve:
	$(PY) -m benchmarks.serve_qps

# durability: supervised service snapshots warm, crashes mid-drain,
# restores (snapshot + WAL replay) and finishes the workload — restore
# latency + recovery QPS rows merge into the persistent trajectory
bench-crash:
	$(PY) -m benchmarks.serve_qps --crash-resume --json $(BENCH)

# open-loop latency under load (smoke sizes): Poisson arrivals against
# the continuous-batching loop, p50/p99 vs offered QPS, product axis vs
# the single-axis drain — rows carry offered_qps/p99_ms in the
# trajectory (schema checked by tests/test_continuous.py)
bench-latency:
	$(PY) -m benchmarks.serve_qps --open-loop --kinds bfs --qps 20,50 \
		--duration 1.0 --scale 6 --tenants 4 --json $(BENCH)

# wavescope demo: mixed-tenant continuous-batching run with tracing
# forced on -> TRACE_serve.json (Chrome/Perfetto; open in
# https://ui.perfetto.dev) + METRICS_serve.prom/.json (schema-checked
# before writing; see README "Observability")
trace:
	$(PY) -m repro.obs.dump
